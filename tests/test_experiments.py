"""Experiment-matrix subsystem (ISSUE 2): plan expansion determinism,
shard-vs-serial record identity, and resume-after-partial-run artifact
identity. ISSUE 3 adds property-style seed-derivation determinism
(axis reordering, re-expansion, spawn-vs-fork pools) and the mid-plan
interruption/resume byte-identity test."""
import dataclasses
import json
import multiprocessing

import pytest

from repro.experiments import (Cell, ExperimentStore, GridSpec, PLANS,
                               PlanRunner, get_plan)
from repro.experiments.plan import cell_seed, ladder_plan
from repro.experiments.store import backfill_theta


def _mini_spec(**over):
    kw = dict(name="mini", archs=("llama31-8b", "qwen3-30b-a3b"),
              hws=("tpu-v5e",), quants=("bf16",), ladder=(5, 50),
              seed=0, protocol="smoke", max_batch=64, num_pages=8192)
    kw.update(over)
    return GridSpec(**kw)


# ---- expansion determinism -------------------------------------------


def test_expansion_deterministic_and_seeded():
    """Same spec -> same cell list, same derived seeds; the plan seed and
    every grid coordinate perturb the derivation."""
    a, b = _mini_spec().expand(), _mini_spec().expand()
    assert a == b
    assert [c.cell_id for c in a.cells] == [c.cell_id for c in b.cells]
    assert len({c.cell_id for c in a.cells}) == len(a.cells)
    for c in a.cells:
        assert c.seed == cell_seed(0, c.group_key, c.lam)
    # a different plan seed moves every cell seed
    c = _mini_spec(seed=123).expand()
    assert [x.seed for x in c.cells] != [x.seed for x in a.cells]
    assert [x.cell_id for x in c.cells] == [x.cell_id for x in a.cells]
    # ladder cells within a group differ only by the lam-derived offset
    g0 = [x for x in a.cells if x.arch == "llama31-8b"]
    assert g0[1].seed - g0[0].seed == int(50 * 1000) - int(5 * 1000)


def test_paper_plans_have_paper_cell_counts():
    h100, a100 = get_plan("paper_h100"), get_plan("paper_a100")
    assert len(h100) == 42 and all(c.hw == "tpu-v5p" for c in h100.cells)
    assert len(a100) == 56 and all(c.hw == "tpu-v5e" for c in a100.cells)
    for plan in (h100, a100):
        assert len({c.cell_id for c in plan.cells}) == len(plan)
        assert {c.quant for c in plan.cells} == {"bf16", "fp8"}
        assert {c.lam for c in plan.cells} == {1, 5, 10, 25, 50, 100, 200}
        # price book is baked per cell: chips scale the hourly price
        for c in plan.cells:
            from repro.core.pricing import chip_hour_price
            assert c.price_per_hr == chip_hour_price(c.hw, c.n_chips)


def test_plan_transform_maps_cells():
    plan = _mini_spec().expand()
    doubled = plan.transform(
        lambda c: dataclasses.replace(c, n_chips=2), suffix="_x2")
    assert doubled.name == "mini_x2"
    assert all(c.n_chips == 2 for c in doubled.cells)
    assert [c.seed for c in doubled.cells] == [c.seed for c in plan.cells]


def test_ladder_plan_uses_raw_sweep_seeds():
    """The lambda_sweep compatibility path must keep the historical
    `seed + int(lam*1000)` derivation untouched."""
    plan = ladder_plan(ladder=(1, 10, 50), seed=7, arch="llama31-8b",
                      config="C1", model="llama31-8b", hw="tpu-v5e")
    assert [c.seed for c in plan.cells] == [7 + 1000, 7 + 10000, 7 + 50000]


# ---- seed-derivation determinism properties (ISSUE 3) ----------------


def _cell_identity(cell: Cell):
    """The derived identity a worker must agree on with its parent."""
    return cell.cell_id, cell.seed, cell.fingerprint()


@pytest.mark.parametrize("plan_name", sorted(PLANS))
def test_plan_reexpansion_stable(plan_name):
    """Property: re-expanding any registered plan yields identical cells,
    seeds and fingerprints — the resume contract rests on this."""
    a, b = get_plan(plan_name), get_plan(plan_name)
    assert a == b
    assert [_cell_identity(c) for c in a.cells] == \
        [_cell_identity(c) for c in b.cells]


def test_seeds_invariant_under_axis_reordering():
    """Property: a cell's seed/fingerprint depend on its coordinates, not
    on where the grid walker encounters it — reversing every axis (and
    the override maps) permutes the cell list but changes no cell."""
    spec = _mini_spec(hws=("tpu-v5e", "tpu-v6e"), quants=("bf16", "fp8"),
                      n_chips_by_arch_hw=(("qwen3-30b-a3b", "tpu-v5e", 2),))
    fwd = spec.expand()
    rev = dataclasses.replace(
        spec, archs=spec.archs[::-1], hws=spec.hws[::-1],
        quants=spec.quants[::-1], ladder=spec.ladder[::-1],
        io_shapes=spec.io_shapes[::-1],
        n_chips_by_arch_hw=spec.n_chips_by_arch_hw[::-1]).expand()
    by_id_f = {c.cell_id: c for c in fwd.cells}
    by_id_r = {c.cell_id: c for c in rev.cells}
    assert set(by_id_f) == set(by_id_r) and len(by_id_f) == len(fwd.cells)
    assert [c.cell_id for c in fwd.cells] != [c.cell_id for c in rev.cells]
    for cid, c in by_id_f.items():
        assert by_id_r[cid] == c
        assert _cell_identity(by_id_r[cid]) == _cell_identity(c)


@pytest.mark.parametrize("method", ["fork", "spawn"])
def test_cell_identity_stable_across_pool_start_methods(method):
    """Property: seeds and fingerprints derived inside spawn/fork workers
    match the parent's (CRC32 + sha256, never hash()) — a sharded run can
    never disagree with the plan about which cell it just finished."""
    if method not in multiprocessing.get_all_start_methods():
        pytest.skip(f"{method} unavailable on this platform")
    plan = get_plan("mini_crosshw")
    want = [_cell_identity(c) for c in plan.cells]
    ctx = multiprocessing.get_context(method)
    with ctx.Pool(2) as pool:
        got = pool.map(_cell_identity, plan.cells)
    assert got == want


# ---- shard-vs-serial identity ----------------------------------------


def test_sharded_records_match_serial_on_mini_plan():
    plan = _mini_spec().expand()
    assert len(plan) == 4
    serial = PlanRunner(plan).run(parallel=False)
    sharded = PlanRunner(plan).run(parallel=True)
    assert len(serial) == len(sharded) == 4
    for a, b in zip(serial, sharded):
        assert dataclasses.asdict(a) == dataclasses.asdict(b)
    # theta_max back-fills per ladder group, not across the whole plan
    by_arch = {}
    for c, r in zip(plan.cells, serial):
        by_arch.setdefault(c.arch, []).append(r)
    for recs in by_arch.values():
        assert all(r.theta_max == max(x.tps for x in recs) for r in recs)


# ---- resumable store -------------------------------------------------


def test_resume_after_partial_run_identical_csv(tmp_path):
    plan = _mini_spec().expand()
    store = ExperimentStore(plan.name, tmp_path)
    PlanRunner(plan, store=store).run(parallel=False)
    full_csv = store.csv_path.read_bytes()
    full_manifest = store.manifest_path.read_bytes()
    assert json.loads(full_manifest)["n_completed"] == 4

    # simulate a killed run: drop two finished cells + the consolidation
    for cell in plan.cells[1:3]:
        store.cell_path(cell).unlink()
    store.csv_path.unlink()
    assert store.completed_ids(plan) == {plan.cells[0].cell_id,
                                         plan.cells[3].cell_id}

    ran = []
    PlanRunner(plan, store=store).run(
        parallel=False,
        progress=lambda c, r, i, n: ran.append(c.cell_id))
    assert sorted(ran) == sorted(c.cell_id for c in plan.cells[1:3])
    assert store.csv_path.read_bytes() == full_csv
    assert store.manifest_path.read_bytes() == full_manifest


class _Interrupted(Exception):
    pass


def test_midplan_interrupt_then_resume_byte_identical(tmp_path):
    """ISSUE 3: kill a mini_crosshw run after K cells (mid-plan, not at a
    tidy boundary), resume, and the consolidated CSV + manifest must be
    byte-identical to an uninterrupted run."""
    plan = get_plan("mini_crosshw")
    ref_store = ExperimentStore(plan.name, tmp_path / "uninterrupted")
    PlanRunner(plan, store=ref_store).run(parallel=False)
    want_csv = ref_store.csv_path.read_bytes()
    want_manifest = ref_store.manifest_path.read_bytes()
    assert json.loads(want_manifest)["n_completed"] == len(plan.cells)

    k = 5
    store = ExperimentStore(plan.name, tmp_path / "interrupted")

    def _kill_after_k(cell, rec, n_done, n_total):
        if n_done >= k:
            raise _Interrupted(cell.cell_id)

    with pytest.raises(_Interrupted):
        PlanRunner(plan, store=store).run(parallel=False,
                                          progress=_kill_after_k)
    # the kill landed after the store write, before consolidation
    assert len(store.completed_ids(plan)) == k
    assert not store.csv_path.exists()

    resumed = []
    records = PlanRunner(plan, store=store).run(
        parallel=False, progress=lambda c, r, i, n: resumed.append(c.cell_id))
    assert len(records) == len(plan.cells)
    assert len(resumed) == len(plan.cells) - k      # only the remainder ran
    assert store.csv_path.read_bytes() == want_csv
    assert store.manifest_path.read_bytes() == want_manifest


def test_stale_fingerprint_forces_rerun(tmp_path):
    plan = _mini_spec().expand()
    store = ExperimentStore(plan.name, tmp_path)
    PlanRunner(plan, store=store).run(parallel=False)
    # the same grid with another seed invalidates every stored cell
    reseeded = _mini_spec(seed=99).expand()
    assert store.completed_ids(reseeded) == set()
    ran = []
    PlanRunner(reseeded, store=store).run(
        parallel=False,
        progress=lambda c, r, i, n: ran.append(c.cell_id))
    assert len(ran) == 4


def test_store_survives_torn_cell_file(tmp_path):
    plan = _mini_spec().expand()
    store = ExperimentStore(plan.name, tmp_path)
    PlanRunner(plan, store=store).run(parallel=False)
    store.cell_path(plan.cells[0]).write_text('{"cell_id": "trunca')
    assert plan.cells[0].cell_id not in store.completed_ids(plan)
    records = PlanRunner(plan, store=store).run(parallel=False)
    assert len(records) == 4


def test_resume_survives_schema_drifted_cell_files(tmp_path):
    """ISSUE 5 regression: a cell file whose JSON parses but whose
    `record` payload is missing or schema-drifted (written by an older
    RunRecord) crashed --resume with TypeError/KeyError. Such files are
    stale: skipped, re-run, and the consolidated artifacts must come out
    byte-identical to an undamaged run."""
    plan = _mini_spec().expand()
    store = ExperimentStore(plan.name, tmp_path)
    PlanRunner(plan, store=store).run(parallel=False)
    want_csv = store.csv_path.read_bytes()
    want_manifest = store.manifest_path.read_bytes()

    # hand-corrupt two cells, keeping their fingerprints valid: one loses
    # the record payload entirely, one drifts to an older schema (fields
    # missing + an unknown one present)
    missing = json.loads(store.cell_path(plan.cells[0]).read_text())
    del missing["record"]
    store.cell_path(plan.cells[0]).write_text(json.dumps(missing))

    drifted = json.loads(store.cell_path(plan.cells[1]).read_text())
    del drifted["record"]["c_eff"]
    del drifted["record"]["tps"]
    drifted["record"]["legacy_field"] = 1.0
    store.cell_path(plan.cells[1]).write_text(json.dumps(drifted))

    assert store.completed_ids(plan) == {c.cell_id for c in plan.cells[2:]}

    ran = []
    PlanRunner(plan, store=store).run(
        parallel=False, progress=lambda c, r, i, n: ran.append(c.cell_id))
    assert sorted(ran) == sorted(c.cell_id for c in plan.cells[:2])
    assert store.csv_path.read_bytes() == want_csv
    assert store.manifest_path.read_bytes() == want_manifest


def test_non_dict_record_payload_is_stale(tmp_path):
    plan = _mini_spec().expand()
    store = ExperimentStore(plan.name, tmp_path)
    PlanRunner(plan, store=store).run(parallel=False)
    blob = json.loads(store.cell_path(plan.cells[0]).read_text())
    blob["record"] = [1, 2, 3]
    store.cell_path(plan.cells[0]).write_text(json.dumps(blob))
    assert plan.cells[0].cell_id not in store.completed_ids(plan)


def test_prune_removes_orphaned_cell_files(tmp_path):
    """ISSUE 5: a plan edit renames cell ids; the superseded files used to
    accumulate forever and even survive --fresh. prune removes exactly the
    files no current cell claims (or claims with a stale fingerprint)."""
    plan = _mini_spec().expand()
    store = ExperimentStore(plan.name, tmp_path)
    PlanRunner(plan, store=store).run(parallel=False)
    want_csv = store.csv_path.read_bytes()

    # a plan edit that renames half the cell ids (50 -> 60 on the ladder;
    # the lam=5 cells are untouched, so their files are shared)
    edited = _mini_spec(ladder=(5, 60)).expand()
    PlanRunner(edited, store=store).run(parallel=False)
    assert len(list(store.dir.glob("cell_*.json"))) == 6   # 4 old + 2 new

    removed = store.prune(edited)
    assert len(removed) == 2            # one orphaned lam=50 file per arch
    survivors = {p.name for p in store.dir.glob("cell_*.json")}
    assert survivors == {store.cell_path(c).name for c in edited.cells}
    # the current plan's cells are all still resumable after the prune
    assert store.completed_ids(edited) == {c.cell_id for c in edited.cells}

    # pruning against the original plan removes the edited-only files and
    # keeps the shared lam=5 cells; a torn orphan goes too
    (store.dir / "cell_bogus.json").write_text('{"fingerprint": tor')
    removed = store.prune(plan)
    assert {p.name for p in removed} == \
        {store.cell_path(c).name for c in edited.cells if c.lam == 60} | \
        {"cell_bogus.json"}
    # consolidation over the survivors re-runs nothing it shouldn't
    ran = []
    PlanRunner(plan, store=store).run(
        parallel=False, progress=lambda c, r, i, n: ran.append(c.cell_id))
    assert sorted(ran) == sorted(c.cell_id for c in plan.cells
                                 if c.lam == 50)
    assert store.csv_path.read_bytes() == want_csv


def test_prune_keeps_stale_fingerprint_files_only_if_current(tmp_path):
    """A cell file whose name matches a current cell but whose fingerprint
    is stale is superseded — prune removes it (the cell re-runs anyway)."""
    plan = _mini_spec().expand()
    store = ExperimentStore(plan.name, tmp_path)
    PlanRunner(plan, store=store).run(parallel=False)
    reseeded = _mini_spec(seed=99).expand()     # same ids, new fingerprints
    removed = store.prune(reseeded)
    assert len(removed) == len(plan.cells)
    assert list(store.dir.glob("cell_*.json")) == []


def test_backfill_theta_partial_groups():
    plan = _mini_spec().expand()
    recs = PlanRunner(plan).run(parallel=False)
    partial = {plan.cells[0].cell_id: dataclasses.replace(recs[0])}
    out = backfill_theta(plan, partial)
    assert len(out) == 1 and out[0].theta_max == out[0].tps


def test_cell_is_picklable_and_builds_engine():
    import pickle
    cell = get_plan("paper_a100").cells[0]
    cell2 = pickle.loads(pickle.dumps(cell))
    assert cell2 == cell
    eng = cell2.engine_spec()()
    assert eng.cfg.max_batch == cell.max_batch


def test_broken_pool_keeps_finished_cells(monkeypatch):
    """A pool that dies mid-run must keep the cells it finished (each
    reported exactly once), warn, and complete only the rest serially."""
    import concurrent.futures

    plan = _mini_spec().expand()
    orig = concurrent.futures.as_completed

    def dies_after_one(futs):
        it = orig(futs)
        yield next(it)
        raise concurrent.futures.process.BrokenProcessPool("injected")

    monkeypatch.setattr(concurrent.futures, "as_completed", dies_after_one)
    seen = []
    with pytest.warns(RuntimeWarning, match="process pool failed"):
        recs = PlanRunner(plan).run(
            parallel=True,
            progress=lambda c, r, i, n: seen.append(i))
    assert seen == [1, 2, 3, 4]          # monotone: no double-reports
    monkeypatch.setattr(concurrent.futures, "as_completed", orig)
    serial = PlanRunner(plan).run(parallel=False)
    assert [dataclasses.asdict(a) for a in recs] == \
        [dataclasses.asdict(b) for b in serial]


def test_task_exception_fails_fast_without_pool_warning():
    """A broken *cell* (not a broken pool) must propagate its own error
    instead of being misread as an infrastructure failure and re-run
    serially behind a misleading warning."""
    import warnings as warnings_mod

    plan = _mini_spec().expand()
    bad = plan.transform(lambda c: dataclasses.replace(c, n_chips="2"))
    with warnings_mod.catch_warnings(record=True) as caught:
        warnings_mod.simplefilter("always")
        with pytest.raises(TypeError):
            PlanRunner(bad).run(parallel=True)
    assert not any("process pool failed" in str(w.message) for w in caught)


def test_failure_times_flow_through_cells():
    """The sweep API accepted failure_times pre-refactor; cells carry it."""
    from repro.core import SimEngineSpec, lambda_sweep
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=8192)
    recs = lambda_sweep(fac, ladder=(10,),
                        requests_per_point=lambda lam: 60,
                        warmup_per_point=lambda lam: 0,
                        failure_times=[0.5], config="C1",
                        model="llama31-8b", hw="tpu-v5e")
    assert recs[0].n_completed == 60
    plan = ladder_plan(ladder=(10,), failure_times=[0.5])
    assert plan.cells[0].failure_times == (0.5,)


def test_unknown_plan_and_protocol_raise():
    with pytest.raises(KeyError, match="unknown plan"):
        get_plan("nope")
    with pytest.raises(KeyError):
        _mini_spec(protocol="nope").expand()


# ---- work-stealing lane scheduler (ISSUE 7) --------------------------


def test_work_stealing_chunker_store_byte_identity(tmp_path, monkeypatch):
    """The shared-deque chunker re-chunks lanes adaptively across pool
    workers; lanes are independent and the store consolidates in plan
    order, so the artifacts must stay byte-identical to the serial
    fixed-width path no matter how the queue drained."""
    import repro.experiments.runner as runner_mod
    from repro.experiments.runner import shutdown_pool
    plan = get_plan("mini_crosshw")
    ref = ExperimentStore(plan.name, tmp_path / "serial")
    PlanRunner(plan, store=ref).run(parallel=False, backend="vector")
    # steal-width floor of 1 + tiny cap -> many 1-2 cell chunks through
    # the shared queue, exercising refill-on-completion and the final
    # ragged chunk
    monkeypatch.setattr(runner_mod, "MIN_FLEET_LANE_WIDTH", 1)
    shutdown_pool()
    stolen = ExperimentStore(plan.name, tmp_path / "stolen")
    PlanRunner(plan, store=stolen).run(parallel=True, backend="vector",
                                       max_workers=2, lane_width=2)
    shutdown_pool()
    assert ref.csv_path.read_bytes() == stolen.csv_path.read_bytes()
    assert ref.manifest_path.read_bytes() == stolen.manifest_path.read_bytes()
    for cell in plan.cells:
        assert ref.cell_path(cell).read_bytes() == \
            stolen.cell_path(cell).read_bytes()


# ---- Monte-Carlo ensemble axis (ISSUE 7) -----------------------------


def test_seed_offset_zero_preserves_base_plan():
    """Offset 0 stays out of cell ids, seed keys and fingerprints: the
    ensemble plan's base replicate is the historical plan, cell for
    cell."""
    base = get_plan("mini_2x2")
    ens = get_plan("mini_ensemble")
    base_rep = [c for c in ens.cells if c.seed_offset == 0]
    assert [c.cell_id for c in base_rep] == [c.cell_id for c in base.cells]
    assert [c.seed for c in base_rep] == [c.seed for c in base.cells]
    # fingerprint ignores the default-zero offset (stores committed
    # before the axis existed keep resuming) but keys on nonzero ones
    c0 = base.cells[0]
    spec = dataclasses.asdict(c0)
    spec.pop("seed_offset")
    for k in ("profile_kind", "profile_knots", "profile_period_s",
              "profile_args"):
        spec.pop(k)     # default-empty lambda(t) axis: same rule (ISSUE 8)
    for k in ("class_mix", "ovl_brownout_depth", "ovl_shed_depth",
              "ovl_recover_depth", "ovl_ttft_slo_s", "ovl_brownout_max_new",
              "ovl_brownout_shed_floor", "ovl_shed_floor"):
        spec.pop(k)     # default-off overload axis: same rule (ISSUE 9)
    import hashlib
    legacy = hashlib.sha256(
        json.dumps(spec, sort_keys=True).encode()).hexdigest()[:16]
    assert c0.fingerprint() == legacy
    assert dataclasses.replace(c0, seed_offset=1).fingerprint() != legacy
    assert dataclasses.replace(c0, profile_kind="diurnal").fingerprint() \
        != legacy


def test_seed_offsets_draw_independent_streams():
    ens = get_plan("mini_ensemble")
    assert len(ens.cells) == 16 and len(ens.groups()) == 8
    by_lam_offsets = {}
    for c in ens.cells:
        by_lam_offsets.setdefault((c.arch, c.lam), []).append(c)
    for (_, _), reps in by_lam_offsets.items():
        assert len(reps) == 4
        assert len({c.seed for c in reps}) == 4          # distinct streams
        assert len({c.cell_id for c in reps}) == 4
        # replicates share everything but the arrival realization
        assert len({(c.n_requests, c.warmup, c.max_batch) for c in reps}) == 1
    # nonzero offsets tag the id, and each offset is its own ladder group
    assert sorted({c.seed_offset for c in ens.cells}) == [0, 1, 2, 3]
    for c in ens.cells:
        assert (f"_s{c.seed_offset}" in c.cell_id) == (c.seed_offset > 0)


def test_paper_ensemble_plan_shape():
    plan = get_plan("paper_ensemble")
    assert len(plan.cells) == 2016                # 18 groups x 7 lams x 16
    assert len({c.cell_id for c in plan.cells}) == 2016
    assert len(plan.groups()) == 288              # 18 x 16 ladder groups
    combos = {(c.arch, c.hw, c.quant) for c in plan.cells}
    assert len(combos) == 18
    assert {c.seed_offset for c in plan.cells} == set(range(16))
