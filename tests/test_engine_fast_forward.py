"""Event-driven fast-forward scheduler equivalence (ISSUE 1).

The fast path must reproduce the reference per-token loop exactly:
identical scheduling decisions (admissions, completions, failure
re-queues) and timings within float-rounding tolerance — across Poisson
and bursty gamma arrivals, failure injection, horizon truncation and
re-entrant runs. Plus the closed-form `decode_time_multi` against the
per-step sum, and the satellite regressions (fail_running before run,
MetricsRegistry.reset)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import (ArrivalSpec, Engine, EngineConfig, SimExecutor,
                           synth_requests)
from repro.serving.request import RequestState
from repro.simulate import StepTimeModel, V5E, V5P

RTOL = 1e-9


def _engine(fast_forward, arch="llama31-8b", hw=V5E, max_batch=32,
            num_pages=8192, max_pages_per_seq=64, **ecfg_kw):
    cfg = get_config(arch)
    stm = StepTimeModel(cfg, hw)
    return Engine(EngineConfig(max_batch=max_batch, page_size=16,
                               num_pages=num_pages,
                               max_pages_per_seq=max_pages_per_seq,
                               fast_forward=fast_forward, **ecfg_kw),
                  SimExecutor(cfg, stm))


def _run_pair(spec, *, horizon=None, failure_times=(), **ekw):
    out = []
    for ff in (False, True):
        eng = _engine(ff, **ekw)
        reqs = synth_requests(spec)
        eng.run(reqs, horizon=horizon, failure_times=failure_times)
        out.append((eng, reqs))
    return out


def _assert_equivalent(ref, fast):
    (eref, rref), (efast, rfast) = ref, fast
    assert abs(eref.t - efast.t) <= RTOL * max(1.0, eref.t)
    assert np.isclose(eref.mean_inflight(), efast.mean_inflight(),
                      rtol=RTOL, atol=1e-12)
    for a, b in zip(rref, rfast):
        assert a.state == b.state
        assert a.tokens_out == b.tokens_out
        assert a.retries == b.retries
        assert (a.finish_time is None) == (b.finish_time is None)
        for ta, tb in ((a.finish_time, b.finish_time),
                       (a.first_token_time, b.first_token_time)):
            assert (ta is None) == (tb is None)
            if ta is not None:
                assert abs(ta - tb) <= RTOL * max(1.0, abs(ta))
    for key in ("repro:generation_tokens_total",
                "repro:prompt_tokens_total",
                "repro:request_success_total",
                "repro:request_preempted_total"):
        assert eref.metrics.get(key) == efast.metrics.get(key), key


CASES = [
    pytest.param(dict(lam=2, n_requests=60, seed=0), {}, {}, id="idle"),
    pytest.param(dict(lam=20, n_requests=120, seed=1), {}, {}, id="loaded"),
    pytest.param(dict(lam=80, n_requests=200, seed=2), {}, {},
                 id="saturated"),
    pytest.param(dict(lam=20, n_requests=100, seed=3, process="gamma",
                      cv=2.0), {}, {}, id="bursty-gamma"),
    pytest.param(dict(lam=15, n_requests=80, seed=4, io_shape="variable"),
                 {}, dict(max_pages_per_seq=512, num_pages=16384),
                 id="variable-shape"),
    pytest.param(dict(lam=20, n_requests=40, seed=2),
                 dict(failure_times=[0.5, 1.5]), {}, id="failures"),
    pytest.param(dict(lam=20, n_requests=150, seed=5), dict(horizon=4.0),
                 {}, id="horizon-truncated"),
    pytest.param(dict(lam=10, n_requests=50, seed=6),
                 dict(failure_times=[0.3], horizon=12.0), {},
                 id="failures+horizon"),
]


@pytest.mark.parametrize("case,runkw,ekw", CASES)
def test_fast_forward_matches_reference(case, runkw, ekw):
    spec = ArrivalSpec(**case)
    ref, fast = _run_pair(spec, **runkw, **ekw)
    _assert_equivalent(ref, fast)


# ---- idle-regime edges (ISSUE 3) -------------------------------------


def _mk_reqs(arrivals, prompt_len=64, max_new=24):
    from repro.serving.request import Request
    return [Request(rid=i, arrival_time=float(t), prompt_len=prompt_len,
                    max_new_tokens=max_new)
            for i, t in enumerate(arrivals)]


def _run_pair_reqs(arrivals, **ekw):
    out = []
    for ff in (False, True):
        eng = _engine(ff, **ekw)
        reqs = _mk_reqs(arrivals)
        eng.run(reqs)
        out.append((eng, reqs))
    return out


def test_idle_co_arrivals_admitted_in_one_wakeup():
    """Batch and queue both empty, several requests arriving at the same
    instant: the idle jump must land once and admit the whole co-arrival
    group in that wakeup — and still match the reference exactly."""
    ref, fast = _run_pair_reqs([1.0, 1.0, 1.0, 9.0, 9.0])
    _assert_equivalent(ref, fast)
    efast, rfast = fast
    # all co-arrivals share one admission instant (same prefill batch)
    assert len({r.first_token_time for r in rfast[:3]}) == 1
    assert len({r.first_token_time for r in rfast[3:]}) == 1
    # two idle gaps + per-group events only: far below one iteration per
    # token, and below even one iteration per request-arrival pair
    assert efast.n_iterations < ref[0].n_iterations / 4
    assert efast.n_ff_jumps >= 2


def test_arrival_exactly_at_completion_event():
    """An arrival whose timestamp exactly equals a completion event must
    take the same scheduler path on both engines (the fast path treats
    arrivals as non-events while a batch runs; the tie must not let the
    jump overshoot the admission)."""
    probe = _engine(True)
    lone = _mk_reqs([0.0])
    probe.run(lone)
    t_done = lone[0].finish_time
    assert t_done is not None and t_done > 0
    ref, fast = _run_pair_reqs([0.0, t_done])
    _assert_equivalent(ref, fast)
    # the second request was admitted at (not after) the completion time
    assert fast[1][1].first_token_time >= t_done


def test_arrival_during_final_decode_burst():
    """Arrival strictly inside the last decode burst of an otherwise
    idle engine: the burst must stop at the arrival so admission happens
    at the same clock on both paths."""
    probe = _engine(True)
    lone = _mk_reqs([0.0])
    probe.run(lone)
    mid = lone[0].finish_time * 0.61803
    ref, fast = _run_pair_reqs([0.0, mid])
    _assert_equivalent(ref, fast)


@pytest.mark.parametrize("lam", [0.5, 2.0, 5.0])
def test_idle_regime_equivalence_and_speedup(lam):
    """lambda <= 5 (the idle regime the PR 2 follow-up targeted): the
    fast path must stay exactly equivalent to the per-token reference
    while doing a fraction of the scheduler iterations."""
    spec = ArrivalSpec(lam=lam, n_requests=60, seed=11)
    ref, fast = _run_pair(spec)
    _assert_equivalent(ref, fast)
    assert fast[0].n_ff_jumps > 0
    assert fast[0].n_iterations < ref[0].n_iterations / 2


def test_fast_forward_reentrant_horizon_loop():
    """Meter-tick style: repeated run() calls under a growing horizon must
    resume identically on both paths."""
    res = {}
    for ff in (False, True):
        eng = _engine(ff)
        reqs = synth_requests(ArrivalSpec(lam=10, n_requests=100, seed=0))
        h = 0.0
        while any(r.finish_time is None for r in reqs):
            h += 2.0
            eng.run(reqs, horizon=h)
            assert h < 3600
        res[ff] = (eng, reqs)
    _assert_equivalent(res[False], res[True])


def test_fast_forward_littles_law():
    """The jump path must preserve the time-weighted in-flight integral:
    mean_inflight ~= lambda_effective * mean residence."""
    eng = _engine(True, max_batch=128, num_pages=16384)
    reqs = synth_requests(ArrivalSpec(lam=5, n_requests=300, seed=0))
    eng.run(reqs)
    done = [r for r in reqs if r.finish_time is not None]
    lam_eff = len(done) / eng.t
    W = float(np.mean([r.e2e for r in done]))
    N = eng.mean_inflight()
    assert abs(N - lam_eff * W) / max(N, 1e-9) < 0.15, (N, lam_eff * W)


def test_fast_forward_actually_jumps():
    """Sanity: the fast path takes far fewer scheduler iterations than the
    per-token reference on the same workload."""
    (eref, _), (efast, _) = _run_pair(ArrivalSpec(lam=20, n_requests=120,
                                                  seed=1))
    assert efast.n_ff_jumps > 0
    assert efast.n_iterations < eref.n_iterations / 4
    assert efast.n_decode_steps == eref.n_decode_steps


def test_decode_time_multi_matches_stepwise_sum():
    """Closed-form k-step decode sum vs the naive per-step loop."""
    for arch, hw in (("llama31-8b", V5E), ("qwen3-30b-a3b", V5P),
                     ("mixtral-8x7b", V5E)):
        stm = StepTimeModel(get_config(arch), hw)
        for batch in (1, 8, 64, 256):
            for ctx0 in (0.0, 37.5, 512.0, 4096.0):
                for k in (1, 2, 7, 100, 1000):
                    want = sum(stm.decode_time(batch, ctx0 + i)
                               for i in range(k))
                    got = stm.decode_time_multi(batch, ctx0, k)
                    assert got == pytest.approx(want, rel=1e-9), \
                        (arch, batch, ctx0, k)
    assert stm.decode_time_multi(8, 100.0, 0) == 0.0
    assert stm.decode_time_multi(0, 0.0, 5) == \
        pytest.approx(5 * stm.decode_time(0, 0.0))


def test_real_executor_fallback_keeps_fast_path_correct():
    """An executor without closed-form jumps (decode_multi loops per step)
    still completes everything under the fast scheduler."""

    class SteppingSim(SimExecutor):
        """Sim timing, but per-step decode_multi like RealExecutor."""
        needs_tokens = True

        def decode_multi(self, tokens, active, block_tables, context_lens,
                         max_steps, time_budget=None):
            cur = np.array(tokens)
            total, steps = 0.0, 0
            ctx = np.array(context_lens)
            while steps < int(max_steps):
                nxt, dt = self.decode(cur, active, block_tables,
                                      context_lens=ctx)
                cur[active] = nxt[active]
                ctx[active] += 1
                total += dt
                steps += 1
                if time_budget is not None and total >= time_budget:
                    break
            return cur, total, max(steps, 1)

    cfg = get_config("llama31-8b")
    stm = StepTimeModel(cfg, V5E)
    results = {}
    for ex in (SimExecutor(cfg, stm), SteppingSim(cfg, stm)):
        eng = Engine(EngineConfig(max_batch=32, page_size=16,
                                  num_pages=8192, max_pages_per_seq=64,
                                  fast_forward=True), ex)
        reqs = synth_requests(ArrivalSpec(lam=20, n_requests=60, seed=7))
        eng.run(reqs)
        results[type(ex).__name__] = (eng, reqs)
    _assert_equivalent(results["SimExecutor"], results["SteppingSim"])


def test_fail_running_before_run_does_not_raise():
    """Satellite: `_requeue` is initialised in __init__, so a driver can
    inject a failure before ever calling run()."""
    eng = _engine(True)
    reqs = synth_requests(ArrivalSpec(lam=5, n_requests=3, seed=0))
    r = reqs[0]
    slot = eng.pm.admit(r.prompt_len, r.max_new_tokens)
    r.slot = slot
    eng.slot_req[slot] = r
    eng.fail_running(1.0)                       # must not raise
    assert eng._requeue and eng._requeue[0] is r
    assert r.state == RequestState.QUEUED
    # the re-queued request is picked up by a subsequent run()
    eng.run(reqs)
    assert all(q.finish_time is not None for q in reqs)


def test_metrics_reset_clears_gauges_and_keeps_bound_hists():
    """Satellite: reset() flushes counters, gauges AND histogram contents
    (in place, so the engine's pre-bound histogram refs stay live)."""
    eng = _engine(True)
    reqs = synth_requests(ArrivalSpec(lam=10, n_requests=20, seed=1))
    eng.run(reqs)
    m = eng.metrics
    assert m.get("repro:time_seconds") > 0
    assert m.hists["repro:e2e_request_latency_seconds"].n == 20
    m.reset()
    assert m.counters == {} and m.gauges == {}
    assert m.hists["repro:e2e_request_latency_seconds"].n == 0
    # a fresh measured run records into the same (cleared) histograms
    eng.reset_measurement()
    reqs2 = synth_requests(ArrivalSpec(lam=10, n_requests=15, seed=2))
    eng.run(reqs2)
    assert m.hists["repro:e2e_request_latency_seconds"].n == 15
    assert sum(m.hists["repro:e2e_request_latency_seconds"].counts) == 15
