"""ISSUE 8: lambda(t) arrivals, the autoscaling simulator, and the
zero-rate/idle-window bug class.

Covers the frozen thinning stream protocol (per-segment empirical rates,
determinism, byte-identity of constant profiles with the historical
stationary streams), the three satellite regressions (zero/negative
rates, shared_prefix_groups, CostMeter idle windows — each fails on the
pre-fix code), the autoscale controller (lag, warmup billing,
hysteresis, LIFO order cancelling), day pricing (idle windows flagged
inf, the static-vs-autoscaled verdict flip), and plan/analyze wiring
(day cells, profile cells out of the stationary analytics, cross-backend
record identity)."""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.meter import CostMeter
from repro.experiments import PlanRunner, get_plan
from repro.experiments.analyze import crosshw_tables, report
from repro.serving import (ArrivalSpec, AutoscalePolicy, DAY_SCENARIOS,
                           RateProfile, gamma_arrivals, poisson_arrivals,
                           profile_arrivals, price_day, simulate_policy,
                           static_size, static_windows, synth_arrays)
from repro.serving.autoscale import MINI_DAY, PAPER_DAY, quantize_rate


# ---- satellite: zero/negative stationary rates -----------------------


def test_zero_rate_means_no_arrivals():
    """lam=0 must yield an empty stream, not inf/NaN times (pre-fix:
    1/lam minted inf gaps that cumsum'd silently into engine clocks)."""
    rng = np.random.default_rng(0)
    assert poisson_arrivals(rng, 0.0, 50).shape == (0,)
    assert gamma_arrivals(rng, 0.0, 2.0, 50).shape == (0,)
    times, p_in, p_out = synth_arrays(ArrivalSpec(lam=0.0, n_requests=50))
    assert len(times) == len(p_in) == len(p_out) == 0


def test_negative_rate_raises():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match=">= 0"):
        poisson_arrivals(rng, -1.0, 10)
    with pytest.raises(ValueError, match=">= 0"):
        gamma_arrivals(rng, -0.5, 2.0, 10)


# ---- satellite: shared_prefix_groups must not silently no-op ---------


def test_shared_prefix_groups_raises_loudly():
    """Pre-fix the field was accepted and ignored: a 'prefix-sharing'
    cell silently measured plain chat."""
    with pytest.raises(NotImplementedError, match="prefix"):
        synth_arrays(ArrivalSpec(lam=5.0, n_requests=10,
                                 shared_prefix_groups=4))


# ---- satellite: CostMeter idle windows -------------------------------


class _FakeEngine:
    """Minimal Prometheus text source for meter unit tests."""

    def __init__(self):
        self.t = 0.0
        self.tok = 0.0

    def advance(self, dt, tokens):
        self.t += dt
        self.tok += tokens

    def render(self):
        return (f"repro:time_seconds {self.t}\n"
                f"repro:generation_tokens_total {self.tok}\n"
                f"repro:num_requests_running 0\n")


def test_meter_idle_window_flagged_not_dropped():
    """An idle minute (billed seconds, zero tokens) must appear as an
    explicit inf window; pre-fix it was silently dropped (undercounting
    `minutes`) and `summary()` had no `idle_minutes` key at all."""
    eng = _FakeEngine()
    meter = CostMeter(1.2, scrape=eng.render, minute_s=60.0)
    meter.tick()
    eng.advance(60.0, 6000.0)
    meter.tick()
    eng.advance(60.0, 0.0)      # the diurnal trough: billed, idle
    meter.tick()
    eng.advance(60.0, 6000.0)
    meter.tick()
    costs = meter.minute_costs()
    assert len(costs) == 3
    assert sum(1 for c in costs if math.isinf(c)) == 1
    summ = meter.summary()
    assert summ["minutes"] == 3.0
    assert summ["idle_minutes"] == 1.0          # KeyError on pre-fix code
    assert math.isinf(summ["worst_minute"])
    assert summ["swing"] is None                # undefined, not a crash
    assert math.isfinite(summ["best_minute"])
    assert math.isfinite(summ["time_weighted_avg"])


def test_meter_all_busy_swing_defined():
    eng = _FakeEngine()
    meter = CostMeter(1.2, scrape=eng.render, minute_s=60.0)
    meter.tick()
    for tok in (3000.0, 6000.0, 12000.0):
        eng.advance(60.0, tok)
        meter.tick()
    summ = meter.summary()
    assert summ["idle_minutes"] == 0.0
    assert summ["swing"] == pytest.approx(4.0)
    assert math.isfinite(summ["worst_minute"])


# ---- RateProfile: validation + shapes --------------------------------


def test_profile_validation_rejects_bad_shapes():
    with pytest.raises(ValueError):
        RateProfile.piecewise([(60.0, -1.0)]).validate()
    with pytest.raises(ValueError):
        RateProfile.piecewise([(0.0, 5.0)]).validate()
    with pytest.raises(ValueError):
        RateProfile.diurnal(trough=5.0, peak=2.0, period_s=60.0).validate()
    with pytest.raises(ValueError):
        RateProfile.trace([(10.0, 1.0), (5.0, 2.0)]).validate()
    with pytest.raises(ValueError):
        RateProfile(kind="wibble").validate()


def test_profile_rate_at_piecewise_cycles_and_means():
    prof = RateProfile.piecewise([(10.0, 2.0), (10.0, 0.0), (20.0, 8.0)])
    ts = np.array([0.0, 9.9, 10.0, 19.9, 25.0, 40.0, 50.5])
    np.testing.assert_allclose(prof.rate_at(ts),
                               [2.0, 2.0, 0.0, 0.0, 8.0, 2.0, 0.0])
    assert prof.mean_rate() == pytest.approx((20.0 + 160.0) / 40.0)
    assert prof.max_rate() == 8.0


def test_profile_trace_step_hold_and_cycle():
    prof = RateProfile.trace([(0.0, 1.0), (10.0, 4.0)], period_s=20.0)
    np.testing.assert_allclose(
        prof.rate_at(np.array([0.0, 5.0, 10.0, 19.0, 20.0, 31.0])),
        [1.0, 1.0, 4.0, 4.0, 1.0, 4.0])


def test_mmpp_realize_deterministic_and_prefix_stable():
    prof = RateProfile.mmpp(2.0, 20.0, 30.0, 10.0)
    a = prof.realize(seed=7, t_end=100.0)
    b = prof.realize(seed=7, t_end=100.0)
    assert a == b and a.kind == "piecewise"
    longer = prof.realize(seed=7, t_end=500.0)
    assert longer.knots[:len(a.knots) - 1] == a.knots[:-1]  # same prefix
    assert prof.realize(seed=8, t_end=100.0) != a


# ---- thinning: empirical rates + protocol ----------------------------


def test_thinning_empirical_rate_per_segment():
    """The accepted stream must realize each segment's rate, including
    an interior ZERO segment that accepts nothing."""
    prof = RateProfile.piecewise([(30.0, 2.0), (30.0, 0.0), (30.0, 8.0)])
    rng = np.random.default_rng(42)
    times = profile_arrivals(rng, prof, 4000)
    cycles = int(times[-1] // 90.0)             # whole cycles only: the
    times = times[times < cycles * 90.0]        # tail would bias counts
    assert cycles >= 10
    t = np.mod(times, 90.0)
    span = cycles * 30.0
    rate0 = np.sum(t < 30.0) / span
    rate1 = np.sum((t >= 30.0) & (t < 60.0)) / span
    rate2 = np.sum(t >= 60.0) / span
    assert rate1 == 0.0
    assert rate0 == pytest.approx(2.0, rel=0.1)
    assert rate2 == pytest.approx(8.0, rel=0.1)
    assert np.all(np.diff(times) > 0)


def test_thinning_deterministic_for_seed():
    prof = RateProfile.diurnal(1.0, 9.0, period_s=120.0)
    a = profile_arrivals(np.random.default_rng(5), prof, 400)
    b = profile_arrivals(np.random.default_rng(5), prof, 400)
    np.testing.assert_array_equal(a, b)


def test_all_zero_profile_refuses():
    with pytest.raises(ValueError, match="max rate is 0"):
        profile_arrivals(np.random.default_rng(0),
                         RateProfile.piecewise([(60.0, 0.0)]), 10)


def test_decaying_trace_raises_instead_of_spinning():
    """A trace that holds rate 0 forever can never satisfy n — the
    thinning loop must abort after THINNING_MAX_BLOCKS, not spin."""
    prof = RateProfile.trace([(0.0, 5.0), (1.0, 0.0)])  # 1 s of traffic
    with pytest.raises(RuntimeError, match="thinning accepted only"):
        profile_arrivals(np.random.default_rng(0), prof, 10_000)


def test_nonconstant_profile_requires_poisson():
    spec = ArrivalSpec(lam=4.0, n_requests=10, process="gamma", cv=2.0,
                       profile=RateProfile.diurnal(1.0, 8.0, 60.0))
    with pytest.raises(ValueError, match="poisson"):
        synth_arrays(spec)


# ---- byte-identity: constant profile == stationary spec --------------


@pytest.mark.parametrize("process,cv", [("poisson", 1.0), ("gamma", 2.0)])
@pytest.mark.parametrize("io_shape", ["chat", "variable"])
def test_constant_profile_byte_identical(process, cv, io_shape):
    """The committed stores' guarantee: adding the profile layer must not
    move a single byte of any stationary stream."""
    base = ArrivalSpec(lam=7.0, n_requests=200, io_shape=io_shape,
                       process=process, cv=cv, seed=11)
    wrapped = dataclasses.replace(base, profile=RateProfile.constant(7.0))
    for a, b in zip(synth_arrays(base), synth_arrays(wrapped)):
        np.testing.assert_array_equal(a, b)


def test_constant_profile_rate_overrides_lam_label():
    """With a constant profile the profile's rate generates and spec.lam
    is just the record label (profile cells label lam = mean rate)."""
    t_prof, _, _ = synth_arrays(ArrivalSpec(
        lam=99.0, n_requests=100, seed=3,
        profile=RateProfile.constant(2.0)))
    t_plain, _, _ = synth_arrays(ArrivalSpec(lam=2.0, n_requests=100,
                                             seed=3))
    np.testing.assert_array_equal(t_prof, t_plain)


# ---- autoscaler: lag, warmup billing, hysteresis ---------------------

POL = AutoscalePolicy(name="t", target_util=0.5, scale_up_lag_s=60.0,
                      warmup_s=60.0, scale_down_hold_s=120.0,
                      min_replicas=1, max_replicas=8)


def test_desired_sizing_and_floor():
    assert POL.desired(0.0, 10.0) == 1          # floor when idle
    assert POL.desired(4.9, 10.0) == 1          # 4.9/(0.5*10) -> ceil 1
    assert POL.desired(5.1, 10.0) == 2
    assert POL.desired(1e9, 10.0) == 8          # ceiling


def test_scale_up_lag_and_warmup_billing():
    """Demand jumps at w1; the controller sees it at w2 and orders. With
    lag=1 warmup=1 window the order bills at w3 and serves at w4 —
    warming replicas are billed without serving."""
    traj = simulate_policy(POL, [1.0, 20.0, 20.0, 20.0, 20.0, 20.0],
                           window_s=60.0, lam_cap=10.0)
    serving = [fw.serving for fw in traj]
    billed = [fw.billed for fw in traj]
    assert serving == [1, 1, 1, 1, 4, 4]
    assert billed == [1, 1, 1, 4, 4, 4]         # w3: billed > serving
    assert all(fw.billed >= fw.serving for fw in traj)


def test_scale_down_hysteresis_holds_then_releases():
    """Demand drops at w1: want < committed from w2 on, but hold=2
    windows of consecutive low demand must pass before release."""
    traj = simulate_policy(POL, [40.0, 1.0, 1.0, 1.0, 1.0, 1.0],
                           window_s=60.0, lam_cap=10.0)
    serving = [fw.serving for fw in traj]
    assert serving[0] == 8                      # pre-provisioned at w0
    assert serving == [8, 8, 8, 1, 1, 1]        # released only at w3
    assert all(fw.billed == fw.serving for fw in traj)  # no new orders


def test_scale_down_cancels_pending_orders_first():
    """A spike order still warming is cancelled (LIFO) when demand
    collapses — live replicas are shed only after pending ones."""
    pol = AutoscalePolicy(name="x", target_util=0.5, scale_up_lag_s=120.0,
                          warmup_s=120.0, scale_down_hold_s=60.0,
                          min_replicas=1, max_replicas=8)
    # w2 orders 3 more (sees w1's 40); w3+w4 see the collapse and the
    # hold of 1 window cancels the order before it ever bills.
    traj = simulate_policy(pol, [1.0, 40.0, 1.0, 1.0, 1.0],
                           window_s=60.0, lam_cap=10.0)
    assert [fw.serving for fw in traj] == [1, 1, 1, 1, 1]
    assert [fw.billed for fw in traj] == [1, 1, 1, 1, 1]


def test_static_size_and_windows():
    assert static_size(34.0, 11.754, util_sla=0.95) == 4
    assert static_size(34.0, 35.969, util_sla=0.95) == 1
    with pytest.raises(ValueError):
        static_size(10.0, 0.0)
    wins = static_windows(3, [1.0, 0.0], 60.0)
    assert [(w.serving, w.billed, w.lam) for w in wins] == \
        [(3, 3, 1.0), (3, 3, 0.0)]


# ---- price_day: idle windows, saturation, verdict flip ---------------


def _flat_tps(cap, per_req=256.0):
    """Crude measured-throughput stand-in: tokens/s grows linearly with
    offered rate and clips at the saturation capacity."""
    return lambda lam: min(lam, cap) * per_req


def test_price_day_idle_window_inf_not_crash():
    wins = static_windows(2, [4.0, 0.0, 4.0], 3600.0)
    out = price_day(wins, price_per_hr=1.2, tps_at=_flat_tps(10.0),
                    lam_cap=10.0)
    assert out["idle_windows"] == 1
    rows = out["windows"]
    assert math.isinf(rows[1]["c_eff"]) and rows[1]["idle"]
    assert rows[1]["cost_usd"] > 0              # billed while idle
    assert math.isfinite(out["day_c_eff"])      # day total still prices
    assert math.isinf(out["worst_busy_window_c_eff"]) is False


def test_price_day_flags_saturated_windows():
    wins = static_windows(1, [15.0], 3600.0)
    out = price_day(wins, price_per_hr=1.2, tps_at=_flat_tps(10.0),
                    lam_cap=10.0)
    assert out["saturated_windows"] == 1        # excess queues, flagged


def test_price_day_rejects_unmeasured_rates():
    wins = static_windows(1, [5.0], 3600.0)
    with pytest.raises(ValueError, match="measure"):
        price_day(wins, price_per_hr=1.2, tps_at=lambda lam: math.nan)


def test_verdict_flips_between_paper_day_deployments():
    """The committed scenario's design invariant: autoscaling pays on the
    small-capacity footprint (4 static replicas, deep trough) and does
    NOT pay on the big one (1 static replica covers the whole day)."""
    sc = PAPER_DAY
    verdicts = {}
    for dep in sc.deployments:
        tps = _flat_tps(dep.lam_cap)
        day = {name: price_day(traj, price_per_hr=dep.price_per_hr,
                               tps_at=tps, lam_cap=dep.lam_cap)
               for name, traj in sc.trajectories(dep).items()}
        winner = min(day, key=lambda k: day[k]["day_c_eff"])
        verdicts[dep.name] = winner
    assert verdicts["llama31-8b@tpu-v5e x2"] != "static"
    assert verdicts["qwen3-30b-a3b@tpu-v5e x8"] == "static"


def test_rate_ladder_covers_every_visited_rate():
    sc = MINI_DAY
    dep = sc.deployments[0]
    ladder = set(sc.rate_ladder(dep))
    for traj in sc.trajectories(dep).values():
        for fw in traj:
            if fw.lam > 0 and fw.serving > 0:
                assert quantize_rate(fw.lam / fw.serving) in ladder


# ---- plans + analyze wiring ------------------------------------------


def test_day_plans_expand_deterministically():
    for name in ("paper_diurnal", "mini_diurnal"):
        a, b = get_plan(name), get_plan(name)
        assert [c.cell_id for c in a.cells] == [c.cell_id for c in b.cells]
        assert len({c.cell_id for c in a.cells}) == len(a.cells)
        assert [c.seed for c in a.cells] == [c.seed for c in b.cells]
    paper = get_plan("paper_diurnal")
    ladder_rates = {quantize_rate(r)
                    for dep in PAPER_DAY.deployments
                    for r in PAPER_DAY.rate_ladder(dep)}
    assert {c.lam for c in paper.cells} <= ladder_rates


def test_profile_cells_roundtrip_arrival_spec():
    plan = get_plan("mini_diurnal")
    prof_cells = [c for c in plan.cells if c.profile_kind]
    assert len(prof_cells) == 2
    for c in prof_cells:
        spec = c.arrival_spec()
        assert spec.profile is not None and not spec.profile.is_constant
        times, _, _ = synth_arrays(dataclasses.replace(
            spec, n_requests=30))
        assert len(times) == 30 and np.all(np.diff(times) > 0)
        assert "prof-" in c.cell_id


def test_stationary_cells_keep_historical_identity():
    """The profile axis must not leak into any stationary cell's id,
    seed key or fingerprint (committed stores resume on these): a
    default-profile cell hashes exactly like one whose dataclass
    predates the axis."""
    import hashlib
    import json
    for name in ("quickstart", "mini_crosshw"):
        for c in get_plan(name).cells:
            assert c.profile_kind == ""
            assert "prof-" not in c.cell_id
            assert not any(isinstance(k, tuple) and k and k[0] == "profile"
                           for k in c.seed_key)
            spec = dataclasses.asdict(c)
            for k in ("profile_kind", "profile_knots", "profile_period_s",
                      "profile_args"):
                spec.pop(k)
            if not c.seed_offset:
                spec.pop("seed_offset")
            if not c.overloaded:
                # the overload axis (ISSUE 9) follows the same rule
                for k in ("class_mix", "ovl_brownout_depth",
                          "ovl_shed_depth", "ovl_recover_depth",
                          "ovl_ttft_slo_s", "ovl_brownout_max_new",
                          "ovl_brownout_shed_floor", "ovl_shed_floor"):
                    spec.pop(k)
            legacy = hashlib.sha256(json.dumps(
                spec, sort_keys=True).encode()).hexdigest()[:16]
            assert c.fingerprint() == legacy


@pytest.fixture(scope="module")
def mini_records():
    plan = get_plan("mini_diurnal")
    recs = PlanRunner(plan).run(parallel=False, backend="vector")
    assert len(recs) == len(plan.cells)
    return recs


def test_mini_diurnal_runs_and_reports(mini_records):
    """End-to-end smoke: run the mini day store on the fleet backend,
    then the analyze report prices the day and the verdict renders."""
    recs = mini_records
    tables = crosshw_tables(recs)
    rows = tables["diurnal"]
    assert len(rows) == 1
    row = rows[0]
    assert row["scenario"] == "mini_day"
    assert not row["missing_rates"]
    pol_names = {p["policy"] for p in row["policies"]}
    assert pol_names == {"static", "reactive"}
    for p in row["policies"]:
        assert p["idle_windows"] == 1           # the zero window priced
        assert p["day_c_eff"] is not None and p["day_c_eff"] > 0
        busy = [w for w in p["windows"] if not w["idle"]]
        assert all(w["c_eff"] is not None for w in busy)
    assert row["winner"] in pol_names
    text = report(recs)
    assert "cost of a day of traffic" in text
    assert "cheapest day" in text


def test_profile_records_excluded_from_stationary_analytics(mini_records):
    """Non-stationary records (config `profile:`) must not masquerade as
    ladder knots or seed replicates in curves/bands."""
    from repro.planner.curves import fit_curves
    recs = mini_records
    prof_recs = [r for r in recs if r.config.startswith("profile:")]
    assert prof_recs, "mini_diurnal should carry profile smoke cells"
    curves = fit_curves(recs)
    for cu in curves:
        assert not any(r.config.startswith("profile:") for r in cu.records)
    # the two profile cells share the lam=4 label with a stationary cell;
    # pre-exclusion they formed a fake 3-"seed" replicate band group
    assert crosshw_tables(recs)["ensemble_bands"] == []


def test_profile_cell_identical_across_backends():
    """Trace-replay determinism: the same profile cell must produce a
    bit-identical record on the scalar process path and the vectorized
    fleet path (the thinning protocol pins the rng consumption)."""
    plan = get_plan("mini_diurnal")
    keep = [c for c in plan.cells if c.profile_kind] + \
        [c for c in plan.cells if not c.profile_kind][:1]
    small = dataclasses.replace(plan, cells=tuple(keep))
    a = PlanRunner(small).run(parallel=False, backend="process")
    b = PlanRunner(small).run(parallel=False, backend="vector")
    c = PlanRunner(small).run(parallel=True, backend="process")
    for ra, rb, rc in zip(a, b, c):
        assert dataclasses.asdict(ra) == dataclasses.asdict(rb)
        assert dataclasses.asdict(ra) == dataclasses.asdict(rc)
