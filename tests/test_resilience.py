"""Resilience layer (ISSUE 6): chaos + equivalence tests.

Pricing reliability is only trustworthy if the failure machinery is
deterministic and path-independent, so the suite leans on the repo's
equivalence discipline rather than statistics:

* `fail_running` — exact frac=0/1 semantics, engine-seeded victim
  stream determinism, `FailureStream` reproducibility.
* fast-forward vs per-token reference equivalence under crash/recovery,
  client retries (incl. jitter), shedding and deadlines — the same
  contract ISSUE 1 established for the failure-free engine.
* fleet lanes vs the scalar engine: bit-identical RunRecords under
  FailureSpec/RetryPolicy (per-lane fallback path).
* conservation identities: every reject (shed/timeout/engine-kill) is
  answered by exactly one client decision (retry or abandon), and every
  original request terminates (success or abandonment).
* runner chaos: wedged workers time out, killed pools re-dispatch within
  the per-cell retry budget, `kill -9` mid-chunk resumes byte-identical.
* `store.verify` + the `--verify` CLI exit contract.
* planner availability pricing: exact binomial spares, and the flip case
  where the failure-free-cheapest footprint loses under 99.9%.
"""
import dataclasses
import json
import multiprocessing
import os
import signal
import subprocess
import sys
import time
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.records import RunRecord
from repro.core.sweep import SimEngineSpec, run_point
from repro.experiments import (ExperimentStore, GridSpec, PlanRunner,
                               get_plan)
from repro.experiments.analyze import reliability_tables
from repro.experiments.run import main as run_main
import repro.experiments.runner as runner_mod
from repro.experiments.runner import run_cell, shutdown_pool
from repro.planner import (AvailabilityTarget, fit_curves, plan_capacity,
                           spares_needed)
from repro.serving import (ArrivalSpec, Engine, EngineConfig, SimExecutor,
                           synth_requests)
from repro.serving.fleet import FleetPoint, fleet_run_points
from repro.serving.request import Request, RequestState
from repro.serving.resilience import FailureSpec, RetryPolicy
from repro.simulate import StepTimeModel, V5E

RTOL = 1e-9

# the full reject/answer counter set (ISSUE 6) + the pre-existing ones
COUNTERS = ("repro:request_success_total",
            "repro:request_preempted_total",
            "repro:request_failure_total",
            "repro:request_retry_total",
            "repro:request_abandoned_total",
            "repro:request_shed_total",
            "repro:request_timeout_total",
            "repro:generation_tokens_total")


def _engine(fast_forward=True, arch="llama31-8b", max_batch=32,
            num_pages=8192, **ecfg_kw):
    cfg = get_config(arch)
    stm = StepTimeModel(cfg, V5E)
    return Engine(EngineConfig(max_batch=max_batch, page_size=16,
                               num_pages=num_pages, max_pages_per_seq=64,
                               fast_forward=fast_forward, **ecfg_kw),
                  SimExecutor(cfg, stm))


# ---- fail_running: exact fracs + deterministic victim stream ----------


def _half_run(seed=0):
    """An engine stopped mid-flight (horizon) with requests still in
    slots — the re-entrant state fail_running operates on."""
    eng = _engine()
    reqs = synth_requests(ArrivalSpec(lam=40, n_requests=80, seed=seed))
    eng.run(reqs, horizon=1.0)
    assert eng.slot_req, "horizon left no in-flight work; bad fixture"
    return eng, reqs


def test_fail_running_exact_zero_and_one():
    eng, _ = _half_run()
    n_running = len(eng.slot_req)
    eng.fail_running(0.0)
    assert eng.metrics.get("repro:request_preempted_total") == 0
    assert len(eng.slot_req) == n_running        # frac=0 loses nothing
    eng.fail_running(1.0)
    assert eng.metrics.get("repro:request_preempted_total") == n_running
    assert not eng.slot_req                      # frac=1 loses every slot


def test_fail_running_engine_seeded_stream_is_deterministic():
    """Same engine state => same victims, across stacked events, with
    no rng passed (the engine owns one persistent stream)."""
    victims = []
    for _ in range(2):
        eng, reqs = _half_run(seed=3)
        before = dict(eng.slot_req)
        eng.fail_running(0.5)
        eng.fail_running(0.5)        # second draw continues the stream
        gone = [s for s in before if s not in eng.slot_req]
        victims.append(sorted(before[s].rid for s in gone))
    assert victims[0] == victims[1] and victims[0]


def test_fail_running_explicit_rng_overrides_engine_stream():
    victims = []
    for _ in range(2):
        eng, _ = _half_run(seed=3)
        before = dict(eng.slot_req)
        eng.fail_running(0.5, rng=np.random.default_rng(7))
        victims.append(sorted(before[s].rid for s in before
                              if s not in eng.slot_req))
    assert victims[0] == victims[1] and victims[0]


def test_failure_stream_deterministic_and_mttf_scaled():
    spec = FailureSpec(mttf=10.0, mttr=2.0, loss_frac=0.3, seed=5)
    a = [spec.stream().pop() for _ in range(1)]
    runs = []
    for _ in range(2):
        s = spec.stream()
        runs.append([s.pop() for _ in range(6)])
    assert runs[0] == runs[1]
    times = [e.time for e in runs[0]]
    assert times == sorted(times) and times[0] > 0.0
    assert all(e.downtime >= 0.0 and e.frac == 0.3 for e in runs[0])
    assert a[0] == runs[0][0]
    # same seed, 2x mttf => the first crash lands 2x later (scaled draws)
    s2 = dataclasses.replace(spec, mttf=20.0).stream()
    assert np.isclose(s2.pop().time, 2.0 * runs[0][0].time, rtol=1e-12)
    # peek does not consume
    s = spec.stream()
    assert s.peek() is s.peek() and s.pop() == runs[0][0]
    assert spec.availability() == pytest.approx(10.0 / 12.0)


def test_failure_spec_disabled_is_inert():
    off = FailureSpec(mttf=0.0, mttr=5.0, seed=1)
    assert not off.enabled and off.availability() == 1.0
    assert off.stream().peek() is None and off.stream().pop() is None


# ---- fast-forward vs reference under the resilience layer -------------


def _run_pair(spec, *, failure_spec=None, retry=None, horizon=None, **ekw):
    out = []
    for ff in (False, True):
        eng = _engine(ff, **ekw)
        reqs = synth_requests(spec)
        eng.run(reqs, horizon=horizon, failure_spec=failure_spec,
                retry=retry)
        out.append((eng, reqs))
    return out


def _assert_equivalent(ref, fast):
    (eref, rref), (efast, rfast) = ref, fast
    assert abs(eref.t - efast.t) <= RTOL * max(1.0, eref.t)
    for a, b in zip(rref, rfast):
        assert a.state == b.state, (a.rid, a.state, b.state)
        assert a.tokens_out == b.tokens_out
        assert a.retries == b.retries
        assert a.attempts == b.attempts
        for ta, tb in ((a.finish_time, b.finish_time),
                       (a.first_token_time, b.first_token_time),
                       (a.submit_time, b.submit_time)):
            assert (ta is None) == (tb is None)
            if ta is not None:
                assert abs(ta - tb) <= RTOL * max(1.0, abs(ta))
    for key in COUNTERS:
        assert eref.metrics.get(key) == efast.metrics.get(key), key


RESIL_CASES = [
    pytest.param(
        dict(lam=20, n_requests=80, seed=0),
        dict(failure_spec=FailureSpec(mttf=2.0, mttr=0.5, seed=3)),
        {}, "repro:request_preempted_total", id="crash-recovery"),
    pytest.param(
        dict(lam=20, n_requests=80, seed=1),
        dict(failure_spec=FailureSpec(mttf=1.5, mttr=0.25, seed=4),
             retry=RetryPolicy(max_attempts=3, base_delay_s=0.25, seed=11)),
        dict(max_retries=0), "repro:request_retry_total",
        id="crash-plus-client-retry"),
    pytest.param(
        dict(lam=120, n_requests=150, seed=2),
        dict(retry=RetryPolicy(max_attempts=2, base_delay_s=0.5, seed=9)),
        dict(max_queue_depth=4, max_batch=8, num_pages=2048),
        "repro:request_shed_total", id="shed-plus-retry"),
    pytest.param(
        dict(lam=60, n_requests=120, seed=5),
        dict(retry=RetryPolicy(max_attempts=2, base_delay_s=0.25,
                               jitter_s=0.2, seed=13)),
        dict(deadline_s=0.4, max_batch=8, num_pages=2048),
        "repro:request_timeout_total", id="deadline-plus-jittered-retry"),
    pytest.param(
        dict(lam=50, n_requests=120, seed=6, process="gamma", cv=2.0),
        dict(failure_spec=FailureSpec(mttf=1.0, mttr=0.5, loss_frac=0.7,
                                      seed=21),
             retry=RetryPolicy(max_attempts=3, base_delay_s=0.25,
                               jitter_s=0.1, seed=22)),
        dict(max_queue_depth=16, deadline_s=1.0, max_retries=1,
             max_batch=8, num_pages=2048),
        "repro:request_abandoned_total", id="everything-at-once"),
]


@pytest.mark.parametrize("case,runkw,ekw,exercised", RESIL_CASES)
def test_fast_forward_matches_reference_under_resilience(
        case, runkw, ekw, exercised):
    ref, fast = _run_pair(ArrivalSpec(**case), **runkw, **ekw)
    _assert_equivalent(ref, fast)
    # the scenario must actually trip its failure mode on both paths
    assert ref[0].metrics.get(exercised) > 0, exercised


@pytest.mark.parametrize("case,runkw,ekw,exercised", RESIL_CASES)
def test_conservation_identities(case, runkw, ekw, exercised):
    """Every reject is answered exactly once, every original request
    terminates: shed + timeout + engine-kill == retry + abandoned, and
    success + abandoned == n_requests."""
    eng = _engine(True, **ekw)
    reqs = synth_requests(ArrivalSpec(**case))
    eng.run(reqs, **runkw)
    m = eng.metrics
    rejects = (m.get("repro:request_shed_total")
               + m.get("repro:request_timeout_total")
               + m.get("repro:request_failure_total"))
    answers = (m.get("repro:request_retry_total")
               + m.get("repro:request_abandoned_total"))
    assert rejects == answers and rejects > 0
    assert (m.get("repro:request_success_total")
            + m.get("repro:request_abandoned_total")) == len(reqs)
    # client attempt bookkeeping mirrors the retry counter exactly
    assert m.get("repro:request_retry_total") == \
        sum(r.attempts for r in reqs)
    for r in reqs:
        assert (r.finish_time is not None) == (r.state == RequestState.DONE)
        assert r.state in (RequestState.DONE, RequestState.FAILED)


def test_resilience_off_is_bit_identical_to_pre_issue6_engine():
    """Zero-cost when off: passing disabled spec/policy objects must not
    perturb a single scheduling decision or metric."""
    spec = ArrivalSpec(lam=25, n_requests=100, seed=8)
    plain = _engine(True)
    reqs_a = synth_requests(spec)
    plain.run(reqs_a)
    guarded = _engine(True)
    reqs_b = synth_requests(spec)
    guarded.run(reqs_b, failure_spec=FailureSpec(mttf=0.0, seed=99),
                retry=RetryPolicy(max_attempts=0, seed=99))
    assert repr(plain.t) == repr(guarded.t)
    for a, b in zip(reqs_a, reqs_b):
        assert repr(a.finish_time) == repr(b.finish_time)
    for key in COUNTERS:
        assert plain.metrics.get(key) == guarded.metrics.get(key)


def test_preempted_request_requeues_at_fcfs_position():
    """A crash victim re-enters the queue ahead of later arrivals (its
    FCFS position follows its original arrival), not at the tail."""
    eng = _engine(True, max_batch=1, num_pages=2048)
    reqs = [Request(rid=i, arrival_time=t, prompt_len=64,
                    max_new_tokens=64)
            for i, t in enumerate((0.0, 0.01, 0.02))]
    eng.run(reqs, failure_times=[0.2])
    assert eng.metrics.get("repro:request_preempted_total") == 1
    assert reqs[0].retries == 1
    # rid 0 restarts before rid 1 ever gets the slot
    assert reqs[0].finish_time < reqs[1].first_token_time
    assert reqs[1].finish_time < reqs[2].first_token_time


# ---- record-level counters + retry amplification ----------------------


def test_run_point_records_resilience_counters():
    fac = SimEngineSpec("llama31-8b", max_batch=16, num_pages=4096,
                        max_queue_depth=8, deadline_s=1.0)
    spec = ArrivalSpec(lam=40, n_requests=120, seed=4)
    rec = run_point(fac, spec, config="C", model="llama31-8b",
                    hw="tpu-v5e",
                    failure_spec=FailureSpec(mttf=1.0, mttr=0.5, seed=7),
                    retry=RetryPolicy(max_attempts=3, base_delay_s=0.25,
                                      seed=8))
    assert rec.n_retried > 0 and rec.retry_amplification > 1.0
    assert rec.n_completed + rec.n_abandoned == rec.n_requests
    assert rec.goodput_rps == pytest.approx(
        rec.n_completed / rec.window_s)
    # the failure-free twin of the same arrivals delivers more, cheaper
    base = run_point(SimEngineSpec("llama31-8b", max_batch=16,
                                   num_pages=4096),
                     spec, config="C", model="llama31-8b", hw="tpu-v5e")
    assert base.n_completed >= rec.n_completed
    assert base.c_eff <= rec.c_eff
    assert base.n_shed == base.n_timeout == base.n_retried == 0


# ---- fleet lanes vs scalar under failure/retry ------------------------


def _points(cells):
    return [FleetPoint(engine=c.engine_spec(), arrivals=c.arrival_spec(),
                       warmup=c.warmup, horizon=c.horizon,
                       failure_times=c.failure_times,
                       failure_spec=c.failure_spec(),
                       retry=c.retry_policy(), **c.record_kw())
            for c in cells]


def _assert_records_equal(xs, ys, ctx=""):
    assert len(xs) == len(ys)
    for a, b in zip(xs, ys):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        for key in da:
            assert repr(da[key]) == repr(db[key]), \
                (ctx, a.model, a.lam, key, da[key], db[key])


def test_fleet_matches_scalar_under_failure_and_retry():
    cells = list(get_plan("mini_resilience").cells)
    scalar = [run_cell(c) for c in cells]
    fleet = fleet_run_points(_points(cells))
    _assert_records_equal(scalar, fleet, "mini_resilience")
    assert any(r.n_retried > 0 for r in scalar)       # chaos actually ran
    base = next(r for r in scalar if r.mttf == 0 and r.retry_max == 0)
    assert all(r.c_eff >= base.c_eff - 1e-12
               for r in scalar if r.mttf > 0)         # failures inflate


# ---- experiment plans: pairing + zero-cost-off ------------------------


def test_resilience_plans_expand_with_paired_seeds():
    plan = get_plan("paper_resilience")
    assert len(plan.cells) == 35
    resil = [c for c in plan.cells if c.resilient]
    assert len(resil) == 21
    base_by_key = {(c.seed_key, c.lam): c for c in plan.cells
                   if not c.resilient}
    for c in resil:
        assert "_mttf" in c.cell_id
        twin = base_by_key[(c.seed_key, c.lam)]
        # resilience axes are excluded from seed derivation: a resilient
        # cell replays its failure-free sibling's arrival stream, so
        # inflation is a paired comparison, not arrival noise
        assert c.seed == twin.seed and c.cell_id != twin.cell_id
    mini = get_plan("mini_resilience")
    assert len(mini.cells) == 4
    assert sum(c.resilient for c in mini.cells) == 3
    # zero-cost when off: a non-resilient cell carries no failure state
    for c in plan.cells:
        if not c.resilient:
            assert c.mttf == 0.0 and c.mttr == 0.0 and c.retry_max == 0
            assert c.failure_spec() is None and c.retry_policy() is None


def test_resilience_axes_default_off_preserves_historical_seeds():
    spec = GridSpec(name="m", archs=("llama31-8b",), hws=("tpu-v5e",),
                    quants=("bf16",), ladder=(5, 50), seed=0,
                    protocol="smoke", max_batch=64, num_pages=8192)
    a = spec.expand()
    b = dataclasses.replace(spec, mttfs=(0.0,), retry_maxes=(0,)).expand()
    assert [c.seed for c in a.cells] == [c.seed for c in b.cells]
    assert [c.cell_id for c in a.cells] == [c.cell_id for c in b.cells]
    assert not any(c.resilient for c in a.cells)


# ---- reliability tables ----------------------------------------------


def _rec(lam, c_eff, *, mttf=0.0, retry_max=0, n_completed=100,
         n_retried=0, tps=100.0, hw="hw"):
    return RunRecord(
        config="C", model="m", hw=hw, n_chips=1, quant="bf16",
        engine="sim", lam=lam, io_shape="fixed", n_requests=100,
        n_completed=n_completed, window_s=10.0, tps=tps, prompt_tps=tps,
        ttft_p50_ms=50.0, ttft_p90_ms=90.0, ttft_p99_ms=99.0,
        tpot_p50_ms=10.0, tpot_p99_ms=20.0, e2e_p50_ms=500.0,
        e2e_p99_ms=900.0, mean_inflight=2.0, price_per_hr=1.0,
        c_eff=c_eff, theta_max=200.0, mttf=mttf, retry_max=retry_max,
        n_retried=n_retried)


def test_reliability_tables_inflation_and_ordering():
    recs = [_rec(10, 0.20),
            _rec(10, 0.30, mttf=5.0, n_completed=80),
            _rec(10, 0.25, mttf=10.0, n_completed=90, retry_max=3,
                 n_retried=40)]
    rows = reliability_tables(recs)
    assert len(rows) == 2                     # baseline row excluded
    # ascending failure *rate*: mttf=10 (rate .1) before mttf=5 (rate .2)
    assert [r["mttf"] for r in rows] == [10.0, 5.0]
    assert rows[0]["c_eff_inflation"] == pytest.approx(0.25 / 0.20)
    assert rows[1]["c_eff_inflation"] == pytest.approx(0.30 / 0.20)
    assert rows[0]["retry_amplification"] == pytest.approx(1.4)
    assert rows[0]["delivered_frac"] == pytest.approx(0.9)
    assert rows[1]["n_retried"] == 0


def test_committed_paper_resilience_store_prices_reliability():
    """The committed artifact satisfies the acceptance shape: inflation
    >= 1.0 and monotone in failure rate at fixed (lambda, retry budget),
    amplification > 1.0 somewhere under failures with retries."""
    store = ExperimentStore("paper_resilience")
    plan = get_plan("paper_resilience")
    if store.completed_ids(plan) != {c.cell_id for c in plan.cells}:
        pytest.skip("paper_resilience store not committed/complete")
    rows = reliability_tables(store.load_records(plan))
    assert rows
    by_block = {}
    for r in rows:
        by_block.setdefault(
            (r["model"], r["hw"], r["n_chips"], r["lam"],
             r["retry_max"]), []).append(r)
    for block in by_block.values():
        infl = [r["c_eff_inflation"] for r in block]
        assert all(x >= 1.0 - 1e-9 for x in infl), block
        assert infl == sorted(infl), block        # monotone in 1/mttf
    assert any(r["retry_amplification"] > 1.0 for r in rows
               if r["retry_max"] > 0 and r["mttf"] > 0)


# ---- planner: availability pricing ------------------------------------


def test_spares_needed_exact_binomial():
    t = AvailabilityTarget(availability=0.999, replica_availability=0.99)
    assert spares_needed(1, t) == 1     # 1 - 0.01^2 = 0.9999 >= 0.999
    assert spares_needed(2, t) == 1
    assert spares_needed(8, t) == 2
    assert spares_needed(3, AvailabilityTarget(0.9, 0.99)) == 0
    # 8-of-N active at 10% replica availability: no spare count reaches
    # three nines within the _MAX_SPARES cap
    assert spares_needed(8, AvailabilityTarget(0.999, 0.1)) is None


def test_availability_flips_the_cheapest_footprint():
    """The ISSUE-6 planner property: when c(lam/2)/c(lam) < (R+1+s')/
    (R+s) economics, the failure-free winner (R=1) loses to R=2 once a
    spare must be bought — the cost of reliability is a ranking change,
    not just a markup."""
    recs = [_rec(10, 0.30), _rec(20, 0.25),
            # resilient rows at the same coords must NOT disturb curves
            _rec(20, 0.60, mttf=5.0, n_completed=50)]
    curves = fit_curves(recs)
    assert len(curves) == 1 and len(curves[0].records) == 2
    free = plan_capacity(curves, 20.0, max_replicas=2)[0]
    assert free.best.replicas == 1 and free.best.spares == 0
    assert free.best.c_eff == pytest.approx(0.25)
    avail = AvailabilityTarget(availability=0.999,
                               replica_availability=0.99)
    priced = plan_capacity(curves, 20.0, max_replicas=2, avail=avail)[0]
    assert priced.avail is avail and priced.mix is None
    assert priced.best.replicas == 2 and priced.best.spares == 1
    # R=2 + 1 spare: 0.25@lam10 * 3/2 = 0.375 < R=1 + 1 spare: 0.25*2
    assert priced.best.c_eff == pytest.approx(0.30 * 3 / 2)
    assert priced.best.fleet_price_per_hr == pytest.approx(3.0)
    loser = [o for o in priced.ranked if o.replicas == 1][0]
    assert loser.spares == 1 and loser.c_eff == pytest.approx(0.50)
    assert priced.best.availability >= 0.999


def test_committed_store_flip_at_lambda_30():
    """On the committed paper_resilience curves the v5e x2 footprint's
    cheapest replica count flips at lambda=30 under 99.9%."""
    store = ExperimentStore("paper_resilience")
    plan = get_plan("paper_resilience")
    if store.completed_ids(plan) != {c.cell_id for c in plan.cells}:
        pytest.skip("paper_resilience store not committed/complete")
    curves = [c for c in fit_curves(store.load_records(plan))
              if c.hw == "tpu-v5e"]
    free = plan_capacity(curves, 30.0)[0]
    avail = AvailabilityTarget(0.999, 0.99)
    priced = plan_capacity(curves, 30.0, avail=avail)[0]
    key_free = (free.best.hw, free.best.n_chips, free.best.replicas)
    key_avail = (priced.best.hw, priced.best.n_chips,
                 priced.best.replicas)
    assert key_free != key_avail
    assert priced.best.spares >= 1


# ---- runner chaos: wedged workers, pool suicide, re-dispatch budget ---


def _mini_plan(**over):
    kw = dict(name="mini_resil_runner", archs=("llama31-8b",),
              hws=("tpu-v5e",), quants=("bf16",), ladder=(5, 50),
              seed=0, protocol="smoke", max_batch=64, num_pages=8192)
    kw.update(over)
    return GridSpec(**kw).expand()


_real_run_cell = run_cell


def _wedged_run_cell(cell, *args, **kw):
    if multiprocessing.parent_process() is not None:
        time.sleep(300)                          # pragma: no cover
    return _real_run_cell(cell, *args, **kw)


def _suicidal_run_cell(cell, *args, **kw):
    if multiprocessing.parent_process() is not None:
        os.kill(os.getpid(), signal.SIGKILL)     # pragma: no cover
    return _real_run_cell(cell, *args, **kw)


@pytest.mark.skipif("fork" not in multiprocessing.get_all_start_methods(),
                    reason="fork start method unavailable")
def test_wedged_worker_times_out_and_falls_back_serially():
    """A pool whose workers hang forever must be declared wedged after
    `worker_timeout`, killed, and (budget exhausted) completed serially
    with correct records."""
    plan = _mini_plan().transform(
        lambda c: dataclasses.replace(c, cell_retries=0), suffix="")
    shutdown_pool()                   # fresh pool inherits the patch
    old = runner_mod.run_cell
    runner_mod.run_cell = _wedged_run_cell
    try:
        with pytest.warns(RuntimeWarning, match="wedged"):
            recs = PlanRunner(plan).run(parallel=True, mp_context="fork",
                                        worker_timeout=1.0)
    finally:
        runner_mod.run_cell = old
        shutdown_pool(kill=True)
    serial = PlanRunner(plan).run(parallel=False)
    _assert_records_equal(recs, serial, "wedged")


@pytest.mark.skipif("fork" not in multiprocessing.get_all_start_methods(),
                    reason="fork start method unavailable")
def test_worker_suicide_exhausts_budget_then_serial():
    """kill -9 inside every worker: BrokenProcessPool each round, per-cell
    re-dispatch budget honoured, then the serial path finishes the run."""
    plan = _mini_plan().transform(
        lambda c: dataclasses.replace(c, cell_retries=1), suffix="")
    shutdown_pool()
    old = runner_mod.run_cell
    runner_mod.run_cell = _suicidal_run_cell
    try:
        with pytest.warns(RuntimeWarning, match="process pool failed"):
            recs = PlanRunner(plan).run(parallel=True, mp_context="fork")
    finally:
        runner_mod.run_cell = old
        shutdown_pool(kill=True)
    serial = PlanRunner(plan).run(parallel=False)
    _assert_records_equal(recs, serial, "suicide")


# ---- store.verify + CLI exit contract ---------------------------------


def test_store_verify_reports_each_failure_mode(tmp_path):
    plan = _mini_plan()
    store = ExperimentStore(plan.name, tmp_path)
    PlanRunner(plan, store=store).run(parallel=False)
    clean = store.verify(plan)
    assert clean == {"issues": [], "missing": []}

    store.cell_path(plan.cells[0]).write_text('{"cell_id": "torn')
    blob = json.loads(store.cell_path(plan.cells[1]).read_text())
    blob["fingerprint"] = "stale"
    store.cell_path(plan.cells[1]).write_text(json.dumps(blob))
    (store.dir / "cell_orphan.json").write_text("{}")
    res = store.verify(plan)
    reasons = " ".join(res["issues"])
    assert len(res["issues"]) == 3
    assert "torn/unreadable" in reasons
    assert "fingerprint drift" in reasons
    assert "orphaned" in reasons
    assert res["missing"] == []

    store.cell_path(plan.cells[0]).unlink()
    res = store.verify(plan)
    assert any("never ran" in m for m in res["missing"])


def test_run_cli_verify_exit_status(tmp_path, capsys):
    store = ExperimentStore("mini_2x2", tmp_path)
    store.dir.mkdir(parents=True, exist_ok=True)
    assert run_main(["--plan", "mini_2x2", "--root", str(tmp_path),
                     "--verify"]) == 0           # missing cells: not corrupt
    (store.dir / "cell_orphan.json").write_text("{}")
    assert run_main(["--plan", "mini_2x2", "--root", str(tmp_path),
                     "--verify"]) == 1
    out = capsys.readouterr().out
    assert "ISSUE" in out and "orphan" in out


# ---- kill -9 mid-chunk, resume byte-identity (chaos tier) -------------


@pytest.mark.slow
@pytest.mark.chaos
def test_kill9_midchunk_then_resume_byte_identical(tmp_path):
    """SIGKILL the runner process mid-plan (workers are writing cell
    blobs themselves), re-invoke with resume, and the consolidated CSV +
    manifest must match an uninterrupted run byte-for-byte."""
    env = dict(os.environ, PYTHONPATH="src")
    repo = Path(__file__).resolve().parents[1]
    cmd = [sys.executable, "-m", "repro.experiments.run",
           "--plan", "mini_2x2", "--workers", "2"]

    clean = tmp_path / "clean"
    subprocess.run(cmd + ["--root", str(clean)], cwd=repo, env=env,
                   check=True, capture_output=True, timeout=300)
    want_csv = (clean / "mini_2x2" / "mini_2x2.csv").read_bytes()
    want_manifest = (clean / "mini_2x2" / "manifest.json").read_bytes()

    chaos = tmp_path / "chaos"
    proc = subprocess.Popen(cmd + ["--root", str(chaos)], cwd=repo,
                            env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    # SIGKILL as soon as the first cell reports: mid-chunk, no cleanup
    deadline = time.time() + 300
    while time.time() < deadline:
        line = proc.stdout.readline()
        if not line:
            break
        if line.startswith("["):
            os.kill(proc.pid, signal.SIGKILL)
            break
    proc.wait(timeout=60)
    assert proc.returncode != 0                   # it really died
    assert not (chaos / "mini_2x2" / "mini_2x2.csv").exists()

    subprocess.run(cmd + ["--root", str(chaos)], cwd=repo, env=env,
                   check=True, capture_output=True, timeout=300)
    assert (chaos / "mini_2x2" / "mini_2x2.csv").read_bytes() == want_csv
    assert (chaos / "mini_2x2" / "manifest.json").read_bytes() == \
        want_manifest
