"""Per-architecture smoke tests: reduced configs, one forward/train/decode
step on CPU, asserting output shapes + finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_config, reduced
from repro.models import (decode_step, init_cache, init_params, prefill,
                          train_loss)

B, S = 2, 16


def _batch(cfg):
    batch = {"tokens": jnp.full((B, S), 3, jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.ones((B, cfg.frontend_len, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.frontend == "vision_patches":
        batch["patches"] = jnp.ones((B, 8, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch, rng):
    cfg = reduced(arch)
    params = init_params(rng, cfg)
    loss, aux = train_loss(params, cfg, _batch(cfg), remat=False)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_decode(arch, rng):
    cfg = reduced(arch)
    params = init_params(rng, cfg)
    logits, cache = prefill(params, cfg, _batch(cfg), max_len=S + 4)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert int(cache["len"][0]) == S
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        logits, cache = decode_step(params, cfg, tok, cache)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    assert int(cache["len"][0]) == S + 3


@pytest.mark.parametrize("arch", ["llama31-8b", "jamba-v0.1-52b",
                                  "xlstm-350m"])
def test_prefill_decode_consistency(arch, rng):
    """Greedy decode after prefill == greedy argmax of the full forward."""
    from repro.models import forward
    cfg = reduced(arch)
    params = init_params(rng, cfg)
    toks = jax.random.randint(rng, (1, 12), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    logits_full, _ = forward(params, cfg, dict(batch, labels=toks))
    logits_pre, _ = prefill(params, cfg, batch, max_len=16)
    # last-position logits must agree between the two paths
    a = jnp.argmax(logits_full[:, -1], -1)
    b = jnp.argmax(logits_pre[:, -1], -1)
    assert jnp.array_equal(a, b), f"{arch}: prefill diverges from forward"


def test_param_counts_match_published():
    expected = {
        "llama31-8b": 8.0e9, "qwen3-30b-a3b": 30.5e9,
        "mixtral-8x7b": 46.7e9, "granite-34b": 34e9,
        "jamba-v0.1-52b": 52e9, "xlstm-350m": 0.35e9,
        "llama4-maverick-400b-a17b": 400e9,
    }
    for name, want in expected.items():
        got = get_config(name).param_count()
        assert abs(got - want) / want < 0.06, (name, got, want)


def test_active_params_ordering():
    """The paper's active-parameter claim presupposes active < total for
    MoE and active == total for dense."""
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if cfg.moe is not None:
            assert cfg.active_param_count() < cfg.param_count(), arch
        else:
            assert cfg.active_param_count() == cfg.param_count(), arch
