"""Fleet backend (ISSUE 4): lane-identity property tests.

Three layers, mirroring the PR-1 equivalence discipline:

* `FleetStepModel` must answer *bitwise* what per-lane `StepTimeModel`s
  answer (`==`, not approx) — the vectorized mirror and the scalar
  roofline must never drift.
* `fleet_run_points` RunRecords must equal the scalar `run_point`
  field-for-field across every mini plan, failure injection, co-arrival
  wakeups, horizon truncation and ragged lane completion (lanes
  finishing at very different sim times must not perturb survivors).
* The `backend="vector"` execution path must produce byte-identical
  store artifacts and reuse one persistent process pool across calls.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sweep import SimEngineSpec, run_point
from repro.experiments import ExperimentStore, PlanRunner, get_plan
from repro.experiments.plan import ladder_plan
from repro.experiments.runner import (execute_cells, run_cell,
                                      shutdown_pool)
from repro.serving.fleet import (FleetEngine, FleetPoint, FleetStepModel,
                                 fleet_run_points)
from repro.simulate import HW_BY_NAME, StepTimeModel


def _points(cells, factory=None):
    return [FleetPoint(engine=factory or c.engine_spec(),
                       arrivals=c.arrival_spec(), warmup=c.warmup,
                       horizon=c.horizon, failure_times=c.failure_times,
                       **c.record_kw())
            for c in cells]


def _assert_records_equal(xs, ys, ctx=""):
    assert len(xs) == len(ys)
    for a, b in zip(xs, ys):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        for key in da:
            # repr-compare: NaN == NaN must pass, 1e-9 drift must not
            assert repr(da[key]) == repr(db[key]), \
                (ctx, a.model, a.hw, a.quant, a.lam, key, da[key], db[key])


# ---- bitwise step-time mirror -----------------------------------------


MODEL_GRID = (("llama31-8b", "tpu-v5e", "bf16", 1),
              ("llama31-8b", "tpu-v5p", "fp8", 2),
              ("qwen3-30b-a3b", "tpu-v6e", "fp8", 2),
              ("qwen3-30b-a3b", "tpu-v5e", "int8", 8),
              ("mixtral-8x7b", "tpu-v5p", "bf16", 2),
              ("xlstm-350m", "tpu-v5e", "bf16", 1))   # kv-free: slope == 0


def test_fleet_step_model_bitwise_vs_scalar():
    """Every lane of the vectorized model must be IEEE-identical to its
    scalar StepTimeModel — exact ==, the tripwire against formula
    drift between `_decode_terms` and its numpy mirror."""
    models = [StepTimeModel(get_config(a), HW_BY_NAME[h], n_chips=n,
                            quant=q) for a, h, q, n in MODEL_GRID]
    fm = FleetStepModel(models)
    rng = np.random.default_rng(42)
    for _ in range(200):
        b = rng.integers(0, 257, len(models))
        ctx = rng.choice([0.0, 37.5, 512.0, 4096.0], len(models))
        k = rng.integers(0, 1200, len(models))
        dt = fm.decode_time(b.astype(float), ctx)
        dtm = fm.decode_time_multi(b.astype(float), ctx, k.astype(float))
        ntok = rng.integers(0, 8193, len(models))
        nreq = rng.integers(0, 9, len(models))
        pf = fm.prefill_time(ntok.astype(float), nreq.astype(float))
        for i, m in enumerate(models):
            assert dt[i] == m.decode_time(int(b[i]), float(ctx[i])), \
                ("decode_time", MODEL_GRID[i], b[i], ctx[i])
            assert dtm[i] == m.decode_time_multi(int(b[i]), float(ctx[i]),
                                                 int(k[i])), \
                ("decode_time_multi", MODEL_GRID[i], b[i], ctx[i], k[i])
            assert pf[i] == m.prefill_time(int(ntok[i]), int(nreq[i])), \
                ("prefill_time", MODEL_GRID[i], ntok[i], nreq[i])


# ---- lane identity vs the scalar engine -------------------------------


@pytest.mark.parametrize("plan_name", ["mini_2x2", "mini_crosshw"])
def test_fleet_records_match_scalar_on_mini_plans(plan_name):
    cells = list(get_plan(plan_name).cells)
    scalar = [run_cell(c) for c in cells]
    fleet = fleet_run_points(_points(cells))
    _assert_records_equal(scalar, fleet, plan_name)


def test_fleet_failure_injection_identity():
    """Failure-tracked lanes walk the same rng.choice stream as the
    scalar fail_running (slot ids evolve identically), so re-queues,
    retries and dropped requests match exactly."""
    plan = ladder_plan(ladder=(5, 20), failure_times=[0.5, 1.5, 3.0],
                       arch="llama31-8b", config="C1", model="llama31-8b",
                       hw="tpu-v5e")
    cells = list(plan.cells)
    scalar = [run_cell(c) for c in cells]
    fleet = fleet_run_points(_points(cells))
    _assert_records_equal(scalar, fleet, "failures")


def test_fleet_stacked_failures_requeue_order():
    """A failure landing while an earlier failure's re-queued requests
    are still draining: the scalar loop front-merges each event's
    victims AHEAD of older leftovers (queue.extendleft), and the fleet
    must prepend identically — with variable shapes the admission order
    is observable in every timing field."""
    big = dict(max_pages_per_seq=512, num_pages=131072, max_prefill_reqs=1)
    cells = []
    for ft in [(0.5, 0.502, 0.504, 0.506), (0.2, 0.21, 0.22),
               (1.0, 1.001)]:
        plan = ladder_plan(ladder=(80,), io_shape="variable",
                           process="gamma", cv=2.0, failure_times=ft,
                           arch="qwen3-30b-a3b", model="qwen3-30b-a3b",
                           hw="tpu-v5p")
        cells += [dataclasses.replace(c, **big) for c in plan.cells]
    scalar = [run_cell(c) for c in cells]
    fleet = fleet_run_points(_points(cells))
    _assert_records_equal(scalar, fleet, "stacked-failures")


def test_fleet_ragged_lanes_identity():
    """One fleet mixing wildly different lanes — idle lambda, saturated
    lambda, horizon-truncated, variable-shape gamma arrivals, failure
    injection, smoke cells — every record must equal its independent
    scalar run: lanes completing early must not perturb survivors."""
    big = dict(max_pages_per_seq=512, num_pages=131072)
    cells = []
    cells += list(ladder_plan(ladder=(1, 80), arch="llama31-8b",
                              model="llama31-8b", hw="tpu-v5e",
                              requests_per_point=lambda lam: 120,
                              warmup_per_point=lambda lam: 15).cells)
    cells += [dataclasses.replace(c, **big) for c in ladder_plan(
        ladder=(10,), io_shape="variable", process="gamma", cv=2.0,
        arch="qwen3-30b-a3b", model="qwen3-30b-a3b", hw="tpu-v5p").cells]
    cells += list(ladder_plan(ladder=(10, 50), horizon=4.0,
                              arch="mixtral-8x7b", model="mixtral-8x7b",
                              hw="tpu-v5e", n_chips=2).cells)
    cells += list(ladder_plan(ladder=(15,), failure_times=[0.3, 2.0],
                              arch="llama31-8b", model="llama31-8b",
                              hw="tpu-v5e").cells)
    cells += list(get_plan("mini_2x2").cells)
    scalar = [run_cell(c) for c in cells]
    fleet = fleet_run_points(_points(cells))
    _assert_records_equal(scalar, fleet, "ragged")


def test_fleet_co_arrival_single_wakeup():
    """Same-instant arrivals into an idle fleet lane must be admitted in
    one wakeup, exactly as the scalar idle-regime path (ISSUE 2)."""
    from repro.serving import Engine, EngineConfig, SimExecutor
    from repro.serving.request import Request

    arrivals = [1.0, 1.0, 1.0, 9.0, 9.0]
    cfg = get_config("llama31-8b")
    stm = StepTimeModel(cfg, HW_BY_NAME["tpu-v5e"])
    eng = Engine(EngineConfig(max_batch=32, page_size=16, num_pages=8192,
                              max_pages_per_seq=64, fast_forward=True),
                 SimExecutor(cfg, stm))
    reqs = [Request(rid=i, arrival_time=float(t), prompt_len=64,
                    max_new_tokens=24) for i, t in enumerate(arrivals)]
    eng.run(reqs)

    spec = SimEngineSpec("llama31-8b", hw="tpu-v5e", max_batch=32,
                         num_pages=8192, max_pages_per_seq=64)
    fe = FleetEngine([spec])
    times = np.asarray(arrivals)
    plens = np.full(len(arrivals), 64, np.int64)
    mnews = np.full(len(arrivals), 24, np.int64)
    fe.load_phase([(times, plens, mnews)], [None], [()])
    fe.run_phase()
    for i, r in enumerate(reqs):
        assert fe.r_first[0, i] == r.first_token_time, (i, r)
        assert fe.r_finish[0, i] == r.finish_time, (i, r)
    # all co-arrivals share one admission instant (one wakeup each)
    assert len(set(fe.r_first[0, :3])) == 1
    assert len(set(fe.r_first[0, 3:])) == 1
    # far fewer rounds than the per-token iteration count
    assert fe.n_rounds < eng.n_decode_steps


def test_fleet_warmup_protocol_identity():
    """Warmup lanes replay run_point's exact protocol (seed + 7777
    stream, reset_measurement at the boundary) while zero-warmup lanes
    sit the phase out."""
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=8192)
    spec_w = dict(ladder=(5, 25), arch="llama31-8b", model="llama31-8b",
                  hw="tpu-v5e",
                  requests_per_point=lambda lam: 150,
                  warmup_per_point=lambda lam: 25)
    plan = ladder_plan(**spec_w)
    cells = list(plan.cells)
    scalar = [run_point(fac, c.arrival_spec(), warmup=c.warmup,
                        **c.record_kw()) for c in cells]
    fleet = fleet_run_points(_points(cells, factory=fac))
    _assert_records_equal(scalar, fleet, "warmup")


# ---- execution backend ------------------------------------------------


def test_vector_backend_store_byte_identity(tmp_path):
    plan = get_plan("mini_crosshw")
    s1 = ExperimentStore(plan.name, tmp_path / "process")
    s2 = ExperimentStore(plan.name, tmp_path / "vector")
    PlanRunner(plan, store=s1).run(parallel=False, backend="process")
    PlanRunner(plan, store=s2).run(parallel=False, backend="vector")
    assert s1.csv_path.read_bytes() == s2.csv_path.read_bytes()
    assert s1.manifest_path.read_bytes() == s2.manifest_path.read_bytes()


class _Killed(Exception):
    pass


def test_vector_backend_midchunk_kill_resume(tmp_path):
    """In-process fleet chunks stream per-cell: a run killed mid-chunk
    keeps every already-finished lane in the store, and resume completes
    the rest to byte-identical artifacts."""
    plan = get_plan("mini_crosshw")
    ref = ExperimentStore(plan.name, tmp_path / "ref")
    PlanRunner(plan, store=ref).run(parallel=False, backend="vector")
    want_csv = ref.csv_path.read_bytes()

    store = ExperimentStore(plan.name, tmp_path / "killed")
    k = 5

    def _kill(cell, rec, n_done, n_total):
        if n_done >= k:
            raise _Killed(cell.cell_id)

    with pytest.raises(_Killed):
        PlanRunner(plan, store=store).run(parallel=False, backend="vector",
                                          progress=_kill)
    # the kill landed mid-chunk, after k per-cell store writes
    assert len(store.completed_ids(plan)) == k
    resumed = []
    PlanRunner(plan, store=store).run(
        parallel=False, backend="vector",
        progress=lambda c, r, i, n: resumed.append(c.cell_id))
    assert len(resumed) == len(plan.cells) - k
    assert store.csv_path.read_bytes() == want_csv


def test_vector_backend_handles_reference_cells():
    """fast_forward=False cells cannot ride a fleet lane; the vector
    backend must route them through the per-cell path transparently."""
    plan = get_plan("mini_2x2")
    mixed = [dataclasses.replace(c, fast_forward=(i % 2 == 0))
             for i, c in enumerate(plan.cells)]
    process = execute_cells(mixed, parallel=False, backend="process")
    vector = execute_cells(mixed, parallel=False, backend="vector")
    _assert_records_equal(process, vector, "mixed-ff")


def test_vector_backend_rejects_unknown():
    with pytest.raises(ValueError, match="unknown backend"):
        execute_cells(list(get_plan("mini_2x2").cells), backend="nope")
    with pytest.raises(ValueError, match="lane_width"):
        execute_cells(list(get_plan("mini_2x2").cells), backend="vector",
                      lane_width=0)


def test_parallel_vector_backend_matches_serial():
    plan = get_plan("mini_crosshw")
    serial = PlanRunner(plan).run(parallel=False, backend="vector")
    pooled = PlanRunner(plan).run(parallel=True, backend="vector",
                                  max_workers=2, lane_width=5)
    _assert_records_equal(serial, pooled, "vector-pool")


def test_persistent_pool_reused_across_calls():
    import repro.experiments.runner as runner_mod
    shutdown_pool()
    cells = list(get_plan("mini_2x2").cells)
    execute_cells(cells, parallel=True, max_workers=2)
    p1 = runner_mod._POOL.get("pool")
    assert p1 is not None
    execute_cells(cells, parallel=True, max_workers=2)
    assert runner_mod._POOL.get("pool") is p1      # same warm pool
    # a different factory keys a fresh pool
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=8192)
    plan = ladder_plan(ladder=(1, 5, 10), arch="llama31-8b",
                       requests_per_point=lambda lam: 40,
                       warmup_per_point=lambda lam: 0)
    execute_cells(list(plan.cells), factory=fac, parallel=True,
                  max_workers=2, backend="process")
    p2 = runner_mod._POOL.get("pool")
    assert p2 is not p1
    shutdown_pool()
    assert runner_mod._POOL.get("pool") is None


def test_lambda_sweep_vector_backend_identity():
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=8192)
    from repro.core import lambda_sweep, parallel_sweep
    kw = dict(ladder=(1, 10, 50),
              requests_per_point=lambda lam: 80,
              warmup_per_point=lambda lam: 0,
              config="C1", model="llama31-8b", hw="tpu-v5e")
    serial = lambda_sweep(fac, **kw)
    vector = parallel_sweep(fac, backend="vector", **kw)
    _assert_records_equal(serial, vector, "sweep-vector")
